"""Train / prefill / decode step factories + sharding-spec builders.

These are the functions the launcher jits and the dry-run lowers for every
(arch × shape × mesh) cell.  Precision follows the paper's two-type
discipline: f32 master weights, bf16 compute copies (grads therefore
all-reduce in bf16 — the gradient-compression knob), f32 loss/optimizer
math, m/v moment dtype per-config.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models import encdec as ED
from repro.models import transformer as TF
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.parallel import sharding as shd

F32 = jnp.float32


def model_module(cfg: ModelConfig):
    return ED if cfg.is_encdec else TF


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def _xent(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Token cross-entropy, f32, mean over all positions."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def loss_fn(cfg: ModelConfig, params, batch, compute_dtype) -> tuple:
    tokens = batch["tokens"]
    if cfg.is_encdec:
        logits, aux = ED.forward(cfg, params, tokens,
                                 frames=batch["frames"],
                                 compute_dtype=compute_dtype)
    else:
        logits, aux = TF.forward(cfg, params, tokens,
                                 prefix_embeds=batch.get("prefix_embeds"),
                                 compute_dtype=compute_dtype)
        if cfg.num_prefix_embeds:   # loss only over the text region
            logits = logits[:, cfg.num_prefix_embeds:]
    loss = _xent(logits[:, :-1], tokens[:, 1:])
    loss = loss + 0.01 * aux["load_balance_loss"]
    return loss, aux


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------

def cast_compute(params, compute_dtype):
    """bf16 compute copy of the MATMUL weights (the leaves the sharding
    rules recognize); norm scales / gates / decay params stay f32."""
    from jax.sharding import PartitionSpec as P

    def cast(path, p):
        names = tuple(str(getattr(k, "key", getattr(k, "idx", "?")))
                      for k in path)
        is_weight = shd.spec_for(names, p.ndim) != P()
        if is_weight and p.dtype == F32:
            return p.astype(compute_dtype)
        return p
    return jax.tree_util.tree_map_with_path(cast, params)


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, *,
                    mesh: Mesh | None = None,
                    compute_dtype=jnp.bfloat16,
                    lr_schedule=None):
    """Returns train_step(state, batch) -> (state, metrics)."""
    schedule = lr_schedule or (lambda s: 1.0)

    def train_step(state, batch):
        with shd.set_mesh(mesh, seq_shard=cfg.seq_shard):
            params = state["params"]

            def lf(cparams):
                return loss_fn(cfg, cparams, batch, compute_dtype)

            cparams = cast_compute(params, compute_dtype)
            (loss, aux), grads = jax.value_and_grad(lf, has_aux=True)(cparams)
            # grads carry compute_dtype -> collectives run compressed; the
            # master update below accumulates in f32 (reliable update, T1)
            new_params, new_opt, gnorm = adamw_update(
                params, grads, state["opt"], opt_cfg,
                lr_scale=schedule(state["opt"]["step"]))
            metrics = {"loss": loss, "grad_norm": gnorm,
                       "load_balance_loss": aux["load_balance_loss"],
                       "step": new_opt["step"]}
            return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def init_train_state(cfg: ModelConfig, key, opt_cfg: AdamWConfig,
                     param_dtype=F32) -> dict:
    params = model_module(cfg).init_params(cfg, key, param_dtype)
    opt_cfg = AdamWConfig(**{**opt_cfg.__dict__,
                             "moment_dtype": cfg.opt_state_dtype})
    return {"params": params, "opt": adamw_init(params, opt_cfg)}


# ---------------------------------------------------------------------------
# Serve steps
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, *, cache_len: int,
                      mesh: Mesh | None = None,
                      compute_dtype=jnp.bfloat16):
    def prefill_step(params, batch):
        with shd.set_mesh(mesh, seq_shard=cfg.seq_shard):
            if cfg.is_encdec:
                return ED.prefill(cfg, params, batch["tokens"],
                                  frames=batch["frames"],
                                  cache_len=cache_len,
                                  compute_dtype=compute_dtype)
            return TF.prefill(cfg, params, batch["tokens"],
                              cache_len=cache_len,
                              prefix_embeds=batch.get("prefix_embeds"),
                              compute_dtype=compute_dtype)

    return prefill_step


def make_decode_step(cfg: ModelConfig, *, mesh: Mesh | None = None,
                     compute_dtype=jnp.bfloat16):
    def decode_step(params, caches, tokens, pos):
        with shd.set_mesh(mesh, seq_shard=cfg.seq_shard):
            logits, caches = model_module(cfg).decode_step(
                cfg, params, tokens, pos, caches,
                compute_dtype=compute_dtype)
            next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return next_tok[:, None], logits, caches

    return decode_step


# ---------------------------------------------------------------------------
# Sharding specs (PartitionSpec trees for jit in_shardings/out_shardings)
# ---------------------------------------------------------------------------

def state_specs(cfg: ModelConfig, state_shape) -> Any:
    """Specs for {"params", "opt"} trees (opt m/v mirror the params)."""
    def spec(path, leaf):
        names = tuple(str(getattr(k, "key", getattr(k, "idx", "?")))
                      for k in path)
        if names and names[-1] == "step":
            return P()
        return shd.spec_for(names, len(leaf.shape))
    return jax.tree_util.tree_map_with_path(spec, state_shape)


def dp_axes_for(mesh: Mesh, batch: int):
    """(pod, data) when the batch divides them, else the largest prefix."""
    dp = shd.batch_axes(mesh)
    if dp is None:
        return None
    total = 1
    for ax in dp:
        total *= mesh.shape[ax]
    if batch % total == 0:
        return dp
    # try data alone (e.g. multi-pod with batch < pods*data)
    if batch % mesh.shape["data"] == 0:
        return ("data",)
    return None


def batch_specs(cfg: ModelConfig, batch_shape, mesh: Mesh) -> Any:
    def spec(path, leaf):
        nd = len(leaf.shape)
        dp = dp_axes_for(mesh, leaf.shape[0])
        return P(dp, *([None] * (nd - 1)))
    return jax.tree_util.tree_map_with_path(spec, batch_shape)


def cache_specs(cfg: ModelConfig, caches_shape, mesh: Mesh) -> Any:
    """KV caches: batch over (pod,data); heads over model when divisible."""
    tp_size = mesh.shape[shd.TP]

    def spec(path, leaf):
        names = [str(getattr(k, "key", getattr(k, "idx", "?")))
                 for k in path]
        name = names[-1] if names else "?"
        nd = len(leaf.shape)
        if nd < 2:
            return P(*([None] * nd))
        dp = dp_axes_for(mesh, leaf.shape[1])  # (L, B, ...) layout
        if name in ("k", "v") and nd == 5:     # (L, B, S, Hkv, hd)
            heads, seq = leaf.shape[3], leaf.shape[2]
            if heads % tp_size == 0:
                return P(None, dp, None, shd.TP, None)
            if cfg.kv_seq_shard and seq % tp_size == 0:
                return P(None, dp, shd.TP, None, None)  # sequence-sharded
            return P(None, dp, None, None, None)
        if name == "S" and nd == 5:            # (L, B, nh, dk, dv)
            heads = leaf.shape[2]
            tp = shd.TP if heads % tp_size == 0 else None
            return P(None, dp, tp, None, None)
        if name == "pos":
            return P(*([None] * nd))
        return P(None, dp, *([None] * (nd - 2)))  # tm_x/cm_x/h/conv
    return jax.tree_util.tree_map_with_path(spec, caches_shape)
