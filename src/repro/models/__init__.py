from repro.models.config import SHAPES, ModelConfig, MoEConfig, ShapeConfig
