"""Sharding rules: map parameter/activation names onto the production mesh.

Strategy (DESIGN.md §5): Megatron tensor parallelism over ``model``,
FSDP-style parameter+optimizer sharding over ``data``, batch data
parallelism over (``pod``, ``data``).  Rules are name-based so every
family's parameter tree gets consistent specs without per-arch tables.
"""

from __future__ import annotations

import contextlib

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

TP = "model"    # tensor-parallel axis
FSDP = "data"   # fully-sharded-parameter axis (also the batch axis)

# spec for the TRAILING dims of each named leaf; leading (stacking) dims
# are padded with None.  3D entries are MoE expert tensors.
_NAME_RULES: dict[str, tuple] = {
    # attention / generic projections
    "wq": (FSDP, TP), "wk": (FSDP, TP), "wv": (FSDP, TP), "wo": (TP, FSDP),
    # MLPs
    "wu": (FSDP, TP), "wg": (FSDP, TP), "wd": (TP, FSDP),
    # embeddings (vocab over TP for parallel logits, d over FSDP)
    "tok": (TP, FSDP), "out": (TP, FSDP),
    # MoE router + experts (experts over TP = expert parallelism)
    "router": (None, TP),
    "moe/wg": (TP, FSDP, None), "moe/wu": (TP, FSDP, None),
    "moe/wd": (TP, None, FSDP),
    # rwkv
    "wr": (FSDP, TP), "ck": (FSDP, TP), "cv": (TP, FSDP), "cr": (FSDP, TP),
    # rg-lru
    "wx": (FSDP, TP), "conv": (None, TP),
}

_CTX = {"mesh": None, "seq_shard": False}


@contextlib.contextmanager
def set_mesh(mesh: Mesh | None, *, seq_shard: bool = False):
    """Activate a mesh for ``constrain`` calls (no-op when None).

    ``seq_shard=True`` additionally shards the sequence axis of residual
    activations over ``model`` (Megatron sequence-parallel analogue): the
    per-layer saved carries and norm intermediates shrink by the TP degree,
    at the cost of per-layer all-gather/reduce-scatter pairs.
    """
    prev = (_CTX["mesh"], _CTX["seq_shard"])
    _CTX["mesh"] = mesh
    _CTX["seq_shard"] = seq_shard
    try:
        yield
    finally:
        _CTX["mesh"], _CTX["seq_shard"] = prev


def batch_axes(mesh: Mesh | None = None, batch: int | None = None):
    """Data-parallel axes; drops axes the batch size cannot divide."""
    mesh = mesh or _CTX["mesh"]
    if mesh is None:
        return None
    axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    if batch is None:
        return axes
    total = 1
    for ax in axes:
        total *= mesh.shape[ax]
    if batch % total == 0:
        return axes
    if batch % mesh.shape["data"] == 0:
        return ("data",)
    return None


def constrain(x: jax.Array, spec: P) -> jax.Array:
    """with_sharding_constraint iff a mesh is active (smoke tests skip)."""
    mesh = _CTX["mesh"]
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_batch(x: jax.Array) -> jax.Array:
    """Constrain (B, S, ...) activations: batch over (pod, data), and — in
    sequence-parallel mode — S over ``model`` when divisible."""
    mesh = _CTX["mesh"]
    if mesh is None:
        return x
    rest = [None] * (x.ndim - 1)
    if (_CTX["seq_shard"] and x.ndim >= 3 and TP in mesh.axis_names
            and x.shape[1] % mesh.shape[TP] == 0 and x.shape[1] > 1):
        rest[0] = TP
    spec = P(batch_axes(mesh, x.shape[0]), *rest)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def tp_axis_for(dim_size: int) -> str | None:
    """``model`` when the dimension divides the TP axis, else replicated."""
    mesh = _CTX["mesh"]
    if mesh is None or TP not in mesh.axis_names:
        return None
    return TP if dim_size % mesh.shape[TP] == 0 else None


def tp_size() -> int:
    """Size of the TP axis in the active mesh (0 when off-mesh)."""
    mesh = _CTX["mesh"]
    if mesh is None or TP not in mesh.axis_names:
        return 0
    return int(mesh.shape[TP])


def constrain_heads(x: jax.Array, head_axis: int) -> jax.Array:
    """Shard (batch, ..., heads, ...) activations: batch over (pod,data),
    the head axis over ``model`` when divisible.  No-op off-mesh."""
    mesh = _CTX["mesh"]
    if mesh is None:
        return x
    tp = tp_axis_for(x.shape[head_axis])
    spec = [None] * x.ndim
    spec[0] = batch_axes(mesh, x.shape[0])
    spec[head_axis] = tp
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def activation_spec(mesh: Mesh, extra: tuple = (None, None)) -> P:
    """(B, S, d)-style activations: batch over (pod, data)."""
    return P(batch_axes(mesh), *extra)


def spec_for(path: tuple[str, ...], ndim: int) -> P:
    """PartitionSpec for a parameter leaf from its tree path."""
    name = path[-1]
    in_moe = any("moe" in p for p in path[:-1]) and "shared" not in path
    key = f"moe/{name}" if in_moe and f"moe/{name}" in _NAME_RULES else name
    base = _NAME_RULES.get(key)
    if base is None or ndim < len(base):
        return P()  # replicated (norm scales, gates, small vectors)
    pad = (None,) * (ndim - len(base))
    return P(*pad, *base)


def _leaf_paths(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves:
        names = tuple(
            getattr(k, "key", getattr(k, "idx", getattr(k, "name", "?")))
            for k in path)
        yield tuple(str(n) for n in names), leaf


def param_specs(params) -> "pytree of P":
    """Tree of PartitionSpecs matching a parameter tree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        names = tuple(str(getattr(k, "key", getattr(k, "idx",
                                                    getattr(k, "name", "?"))))
                      for k in path)
        specs.append(spec_for(names, leaf.ndim if hasattr(leaf, "ndim")
                              else len(leaf.shape)))
    return jax.tree_util.tree_unflatten(treedef, specs)
