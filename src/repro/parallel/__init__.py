from repro.parallel.sharding import (activation_spec, batch_axes, constrain,
                                     param_specs, set_mesh, spec_for)
