from repro.data.synthetic import SyntheticLM, lattice_problem
