"""Deterministic synthetic data pipelines.

* ``SyntheticLM`` — reproducible token/frame/patch batches for the LM
  substrate.  Batch ``i`` is a pure function of (seed, i), so a restarted
  job regenerates the exact stream and can skip ahead to the checkpoint
  step (the data half of fault-tolerant restart).
* ``lattice_problem`` — gauge field + source for the paper's solver.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import lattice as lat
from repro.models.config import ModelConfig


@dataclasses.dataclass
class SyntheticLM:
    cfg: ModelConfig
    batch: int
    seq_len: int
    seed: int = 0
    # "zipf": skewed unigram distribution (learnable signal for the loss
    # curve); "uniform": max-entropy tokens (throughput benchmarking).
    mode: str = "zipf"

    def _key(self, step: int):
        return jax.random.fold_in(jax.random.PRNGKey(self.seed), step)

    def _tokens(self, key, shape):
        v = self.cfg.vocab_size
        if self.mode == "uniform":
            return jax.random.randint(key, shape, 0, v, jnp.int32)
        logits = -1.2 * jnp.log1p(jnp.arange(v, dtype=jnp.float32))
        return jax.random.categorical(key, logits, shape=shape).astype(
            jnp.int32)

    def batch_at(self, step: int, dtype=jnp.float32) -> dict:
        """Batch for a given step index (host arrays; caller shards)."""
        cfg = self.cfg
        key = self._key(step)
        kt, kf = jax.random.split(key)
        out: dict = {}
        if cfg.is_encdec:
            out["tokens"] = self._tokens(kt, (self.batch, self.seq_len))
            out["frames"] = 0.02 * jax.random.normal(
                kf, (self.batch, self.seq_len, cfg.d_model), dtype)
        elif cfg.num_prefix_embeds:
            s_txt = self.seq_len - cfg.num_prefix_embeds
            out["tokens"] = self._tokens(kt, (self.batch, s_txt))
            out["prefix_embeds"] = 0.02 * jax.random.normal(
                kf, (self.batch, cfg.num_prefix_embeds, cfg.d_model), dtype)
        else:
            out["tokens"] = self._tokens(kt, (self.batch, self.seq_len))
        return out

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def lattice_problem(shape: lat.LatticeShape, *, mass: float = 0.1,
                    seed: int = 0, packed: bool = True):
    """(gauge, source) for D x = b — the paper's workload generator."""
    key = jax.random.PRNGKey(seed)
    ku, kb = jax.random.split(key)
    u = lat.random_gauge(ku, shape)
    b = lat.random_spinor(kb, shape)
    if packed:
        return lat.pack_gauge(u), lat.pack_spinor(b)
    return u, b
